"""``repro.obs`` — host-side observability (Spec -> Resolver -> Artifact).

The eighth spec->resolver->artifact package (after plan / serving /
cache / tune / spec / quant / shard):

- :class:`ObsConfig`       — the spec: trace/metrics enables + dump
  paths + the injectable monotonic clock.  ``resolve()`` is the one
  constructor (returns :data:`NULL_OBSERVER` when disabled).
- :class:`Observer`        — the resolver output the serving engines
  call into: per-request lifecycle hooks (submit -> queue-wait ->
  admit -> first token -> per-step decode/verify -> finish), per-launch
  spans stamped with LaunchPlan provenance, structured warnings, and
  occupancy gauges.  :meth:`Observer.shard_view` merges per-shard
  labels onto one clock.
- :class:`Tracer` / :class:`TraceArtifact` — Chrome trace-event JSON
  (Perfetto-loadable), schema-gated by :func:`validate_trace`.
- :class:`MetricsRegistry` — counters / gauges / fixed-bucket
  histograms with a JSON snapshot and Prometheus text exposition;
  the snapshot's ``plan_cache`` section absorbs ``PlanCacheStats``
  (``to_json`` shape preserved).

Everything here is strictly host-side: nothing is traced, jitted, or
placed on device, and the disabled path allocates nothing per step.
"""
from repro.obs.config import ObsConfig, resolve_obs  # noqa: F401
from repro.obs.io import (  # noqa: F401
    atomic_write_json,
    atomic_write_text,
)
from repro.obs.metrics import (  # noqa: F401
    DEFAULT_BUCKETS,
    Family,
    MetricsRegistry,
)
from repro.obs.observer import (  # noqa: F401
    NULL_OBSERVER,
    NullObserver,
    Observer,
    plan_provenance,
)
from repro.obs.trace import (  # noqa: F401
    TraceArtifact,
    Tracer,
    validate_trace,
)
