"""Observer: the engine-facing observability facade.

One Observer per serving topology, resolved from
:class:`~repro.obs.config.ObsConfig`.  It owns the injectable monotonic
clock (one epoch + a shared non-decreasing clamp, so every shard view's
timestamps merge onto ONE timeline), the :class:`~repro.obs.Tracer`
(when tracing is on) and the :class:`~repro.obs.MetricsRegistry` (when
metrics are on), and exposes the narrow ``on_*`` hook surface the
:class:`~repro.serving.ServingEngine` calls at its admission / launch /
finish sites.

Strictly host-side and zero-cost when disabled: the engine guards every
call with ``if self._obs.enabled:``, and the disabled singleton is
:data:`NULL_OBSERVER` (``enabled = False``, every hook a no-op) — no
per-step allocation, nothing inside jitted code, ``policy_eval_count``
stays 0 and greedy streams stay bit-identical with tracing on
(property-tested in ``tests/test_obs.py``).

Sharded topologies call :meth:`Observer.shard_view` once per dp shard:
views share the tracer, registry and clock, bind ``pid = shard`` on
trace tracks and ``shard=d`` labels on every metric series, and the
parent dumps ONE trace + ONE metrics artifact at drain (per-shard
PlanCacheStats ride the snapshot's ``plan_cache`` section through the
``merge_stats_snapshots`` path).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.obs.io import atomic_write_json, atomic_write_text
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer


def plan_provenance(key: Any, plan: Any) -> Dict[str, Any]:
    """JSON-safe LaunchPlan provenance for a launch span's ``args``.

    The four acceptance-critical keys — ``num_splits``, ``mesh_splits``,
    ``kv_dtype``, ``table_version`` — are ALWAYS present (null when the
    launch rode the internal-heuristic fallback and had no plan)."""
    d: Dict[str, Any] = {
        "key": ("/".join(map(str, key)) if isinstance(key, tuple)
                else "fallback" if key is None else str(key)),
        "num_splits": None, "mesh_splits": None, "seq_shard_axis": None,
        "kv_dtype": None, "tuned": None, "table_version": None,
    }
    if plan is not None:
        d.update(num_splits=plan.num_splits,
                 mesh_splits=plan.mesh_splits,
                 seq_shard_axis=(plan.seq_shard_axis
                                 if plan.seq_shard_mesh is not None
                                 else None),
                 tuned=plan.tuned, table_version=plan.table_version,
                 policy=plan.policy, bucket=plan.bucket)
        if plan.impl is not None:
            d["impl"] = plan.impl
        w = plan.workload
        if w is not None:
            d["kv_dtype"] = w.kv_dtype_name
            d["dtype_bytes"] = w.dtype_bytes
    return d


class _Rec:
    """Per-in-flight-request host record (popped at finish)."""
    __slots__ = ("t_submit", "t_admit0", "t_first", "request_id",
                 "prompt_len", "kind", "ntokens")

    def __init__(self, t_submit: int, request_id: int,
                 prompt_len: int) -> None:
        self.t_submit = t_submit
        self.t_admit0: Optional[int] = None
        self.t_first: Optional[int] = None
        self.request_id = request_id
        self.prompt_len = prompt_len
        self.kind: Optional[str] = None
        self.ntokens = 0


class NullObserver:
    """The disabled observer: every hook a no-op, ``enabled = False``
    (engines branch on the flag, so the hot path never even calls in)."""

    enabled = False

    def shard_view(self, pid: int, name: str = "") -> "NullObserver":
        return self

    def now_us(self) -> int:
        return 0

    def on_submit(self, *a: Any, **k: Any) -> None: ...
    def on_admit_start(self, *a: Any, **k: Any) -> None: ...
    def on_admit_end(self, *a: Any, **k: Any) -> None: ...
    def on_launch(self, *a: Any, **k: Any) -> None: ...
    def on_token(self, *a: Any, **k: Any) -> None: ...
    def on_finish(self, *a: Any, **k: Any) -> None: ...
    def on_warning(self, *a: Any, **k: Any) -> None: ...
    def sample_occupancy(self, *a: Any, **k: Any) -> None: ...

    def metrics_snapshot(self, plan_stats: Any = None) -> Dict[str, Any]:
        return {}

    def prometheus(self, plan_stats: Any = None) -> str:
        return ""

    def dump(self, *a: Any, **k: Any) -> None: ...


NULL_OBSERVER = NullObserver()


class Observer:
    """Enabled observer (see module docstring for the contract)."""

    enabled = True

    def __init__(self, *, tracer: Optional[Tracer],
                 metrics: Optional[MetricsRegistry],
                 clock: Optional[Callable[[], float]] = None,
                 process_name: str = "serve", pid: int = 0,
                 labels: Optional[Dict[str, str]] = None,
                 parent: Optional["Observer"] = None) -> None:
        self.tracer = tracer
        self.metrics = metrics
        self.pid = pid
        self.process_name = process_name
        self.labels = dict(labels or {})
        if parent is None:
            self._clock = clock if clock is not None else time.monotonic
            self._epoch = self._clock()
            self._last = [0]            # shared monotonic clamp (views)
        else:
            self._clock = parent._clock
            self._epoch = parent._epoch
            self._last = parent._last
        self._recs: Dict[int, _Rec] = {}
        if tracer is not None:
            # a shard view renames the pid its parent pre-registered
            # under the generic engine name (force=True)
            tracer.ensure_process(pid, process_name,
                                  force=parent is not None)
            tracer.ensure_thread(pid, 0, "launches")
        if metrics is not None:
            self._bind_metrics(metrics)

    def _bind_metrics(self, m: MetricsRegistry) -> None:
        lb = self.labels
        self._m_submitted = m.counter(
            "requests_submitted_total", "requests accepted by submit()"
        ).labels(**lb)
        self._m_finished = m.counter(
            "requests_finished_total",
            "finished requests by finish_reason")
        self._m_tokens = m.counter(
            "tokens_total", "generated tokens emitted").labels(**lb)
        self._m_prefix_rows = m.counter(
            "prefix_shared_rows_total",
            "prompt rows adopted from shared prefix pages").labels(**lb)
        self._m_prefix_bytes = m.counter(
            "prefix_shared_bytes_total",
            "KV bytes those adopted rows did not recompute").labels(**lb)
        self._m_warnings = m.counter(
            "engine_warnings_total",
            "structured engine warnings by code (each occurrence; the "
            "python warnings.warn compat shim still fires once)")
        self._m_ttft = m.histogram(
            "ttft_ms", "time to first token (submit -> first TOKEN), ms"
        ).labels(**lb)
        self._m_tpot = m.histogram(
            "tpot_ms", "time per output token after the first, ms"
        ).labels(**lb)
        self._m_queue_wait = m.histogram(
            "queue_wait_ms", "submit -> admission start, ms").labels(**lb)
        self._m_launch = m.histogram(
            "launch_ms", "wall-clock per launch by kind, ms")
        self._m_launches = m.counter(
            "launches_total", "launches by kind")
        self._m_slots_live = m.gauge(
            "slots_live", "occupied decode slots (last step)").labels(**lb)
        self._m_slots_total = m.gauge(
            "slots_total", "decode slot capacity").labels(**lb)
        self._m_queue_depth = m.gauge(
            "queue_depth", "pending not-yet-admitted requests"
        ).labels(**lb)
        self._m_pages_free = m.gauge(
            "pages_free", "free KV pages (paged layout)").labels(**lb)
        self._m_pages_total = m.gauge(
            "pages_total", "KV page-pool capacity (paged layout)"
        ).labels(**lb)

    # --- clock --------------------------------------------------------------

    def now_us(self) -> int:
        """Microseconds since the (shared) epoch, clamped non-decreasing
        across every view of this observer — one merged timeline."""
        us = int((self._clock() - self._epoch) * 1e6)
        if us < self._last[0]:
            us = self._last[0]
        else:
            self._last[0] = us
        return us

    def shard_view(self, pid: int, name: str = "") -> "Observer":
        """A per-shard view: same tracer / registry / clock, trace
        tracks under ``pid`` and every metric labeled ``shard=pid``."""
        labels = dict(self.labels)
        labels["shard"] = str(pid)
        return Observer(tracer=self.tracer, metrics=self.metrics,
                        process_name=name or f"shard{pid}", pid=pid,
                        labels=labels, parent=self)

    # --- request lifecycle hooks --------------------------------------------

    def on_submit(self, handle: int, request_id: int,
                  prompt_len: int) -> None:
        ts = self.now_us()
        self._recs[handle] = _Rec(ts, request_id, prompt_len)
        if self.tracer is not None:
            self.tracer.ensure_thread(self.pid, handle + 1,
                                      f"req{request_id}")
        if self.metrics is not None:
            self._m_submitted.inc()

    def on_admit_start(self, handle: int) -> None:
        r = self._recs.get(handle)
        if r is None:
            return
        ts = self.now_us()
        r.t_admit0 = ts
        if self.tracer is not None:
            self.tracer.complete(self.pid, handle + 1, "queue_wait",
                                 "request", r.t_submit, ts - r.t_submit)
        if self.metrics is not None:
            self._m_queue_wait.observe((ts - r.t_submit) / 1e3)

    def on_admit_end(self, handle: int, kind: str, shared_rows: int = 0,
                     shared_bytes: int = 0) -> None:
        r = self._recs.get(handle)
        if r is None:
            return
        ts = self.now_us()
        r.kind = kind
        t0 = r.t_admit0 if r.t_admit0 is not None else ts
        if self.tracer is not None:
            args: Dict[str, Any] = {"prefill": kind}
            if shared_rows:
                args["shared_rows"] = int(shared_rows)
            self.tracer.complete(self.pid, handle + 1, "admit",
                                 "request", t0, ts - t0, args)
        if self.metrics is not None and shared_rows:
            self._m_prefix_rows.inc(int(shared_rows))
            self._m_prefix_bytes.inc(int(shared_bytes))

    def on_launch(self, kind: str, key: Any, plan: Any, t0: int,
                  handles: Sequence[int] = ()) -> None:
        """Close one launch span ``[t0, now)`` on the pid's "launches"
        track, stamped with the plan's provenance; ``handles`` mirror
        the span onto each rider's request track (decode/verify rows)."""
        t1 = self.now_us()
        if self.tracer is not None:
            self.tracer.complete(self.pid, 0, kind, "launch", t0, t1 - t0,
                                 plan_provenance(key, plan))
            for h in handles:
                if h in self._recs:
                    self.tracer.complete(self.pid, h + 1, kind, "step",
                                         t0, t1 - t0)
        if self.metrics is not None:
            self._m_launches.inc(1, kind=kind, **self.labels)
            self._m_launch.observe((t1 - t0) / 1e3, kind=kind,
                                   **self.labels)

    def on_token(self, handle: int, index: int) -> None:
        r = self._recs.get(handle)
        if r is None:
            return
        r.ntokens = index + 1
        if index == 0 and r.t_first is None:
            ts = self.now_us()
            r.t_first = ts
            if self.tracer is not None:
                self.tracer.instant(self.pid, handle + 1, "first_token",
                                    "request", ts)
            if self.metrics is not None:
                self._m_ttft.observe((ts - r.t_submit) / 1e3)
        if self.metrics is not None:
            self._m_tokens.inc()

    def on_finish(self, handle: int, reason: str) -> None:
        r = self._recs.pop(handle, None)
        if r is None:
            return
        ts = self.now_us()
        if self.tracer is not None:
            self.tracer.complete(
                self.pid, handle + 1, "request", "request",
                r.t_submit, ts - r.t_submit,
                {"request_id": r.request_id, "prompt_len": r.prompt_len,
                 "prefill": r.kind, "finish_reason": reason,
                 "tokens": r.ntokens})
        if self.metrics is not None:
            self._m_finished.inc(1, reason=reason, **self.labels)
            if r.t_first is not None and r.ntokens > 1:
                self._m_tpot.observe(
                    (ts - r.t_first) / 1e3 / (r.ntokens - 1))

    def on_warning(self, code: str, message: str) -> None:
        """One structured warning occurrence (counted per event — the
        once-per-engine python ``warnings.warn`` compat shim is the
        engine's job, not ours)."""
        if self.tracer is not None:
            self.tracer.instant(self.pid, 0, f"warning:{code}", "warning",
                                self.now_us(),
                                {"message": str(message)[:300]})
        if self.metrics is not None:
            self._m_warnings.inc(1, code=code, **self.labels)

    def sample_occupancy(self, live: int, slots: int, queue_depth: int,
                         free_pages: Optional[int] = None,
                         total_pages: Optional[int] = None) -> None:
        if self.metrics is None:
            return
        self._m_slots_live.set(live)
        self._m_slots_total.set(slots)
        self._m_queue_depth.set(queue_depth)
        if free_pages is not None:
            self._m_pages_free.set(free_pages)
        if total_pages is not None:
            self._m_pages_total.set(total_pages)

    # --- export -------------------------------------------------------------

    def metrics_snapshot(self, plan_stats: Any = None) -> Dict[str, Any]:
        """The JSON metrics artifact: every registry family (series +
        aggregate) plus, when given, the PlanCacheStats section —
        ``PlanCacheStats.to_json()`` verbatim (shape preserved) for a
        single engine, ``{"shards": [...], "aggregate": merge}`` for a
        sharded one."""
        snap: Dict[str, Any] = {
            "metrics": self.metrics.snapshot()
            if self.metrics is not None else {},
        }
        if plan_stats is not None:
            snap["plan_cache"] = plan_stats
        return snap

    def prometheus(self, plan_stats: Any = None) -> str:
        """Prometheus text exposition: the registry families plus the
        absorbed PlanCacheStats scalar counters
        (``repro_plan_cache_<name>``, per-shard labeled + aggregate
        under a sharded topology)."""
        text = (self.metrics.prometheus()
                if self.metrics is not None else "")
        lines: List[str] = []

        def scalars(snap: Dict[str, Any], label: str = "") -> None:
            for k in sorted(snap):
                v = snap[k]
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                lines.append(f"repro_plan_cache_{k}{label} {v:g}")

        if isinstance(plan_stats, dict):
            if "shards" in plan_stats \
                    and isinstance(plan_stats["shards"], list):
                for s in plan_stats["shards"]:
                    d = s.get("shard", 0)
                    scalars(s, '{shard="%s"}' % d)
                scalars(plan_stats.get("aggregate", {}))
            else:
                scalars(plan_stats)
        return text + ("\n".join(lines) + "\n" if lines else "")

    def dump(self, trace_path: Optional[str] = None,
             metrics_path: Optional[str] = None,
             plan_stats: Any = None) -> None:
        """Write the artifacts (atomic).  ``metrics_path`` ending in
        ``.prom``/``.txt`` selects the Prometheus text exposition;
        anything else gets the JSON snapshot."""
        if trace_path and self.tracer is not None:
            self.tracer.artifact().save(trace_path)
        if metrics_path and self.metrics is not None:
            if str(metrics_path).endswith((".prom", ".txt")):
                atomic_write_text(metrics_path,
                                  self.prometheus(plan_stats))
            else:
                atomic_write_json(metrics_path,
                                  self.metrics_snapshot(plan_stats))
