"""ObsConfig: the spec half of the spec -> resolver -> artifact package.

``ObsConfig.resolve()`` is the one constructor every consumer goes
through: it returns the shared :data:`~repro.obs.NULL_OBSERVER`
singleton when nothing is enabled (the zero-cost path — engines branch
on ``observer.enabled`` and never allocate), or an
:class:`~repro.obs.Observer` wiring a :class:`~repro.obs.Tracer`
(``trace`` / ``trace_path``) and/or a
:class:`~repro.obs.MetricsRegistry` (``metrics`` / ``metrics_path``)
onto the injectable monotonic ``clock`` (tests pass a fake clock for
deterministic traces; ``None`` = ``time.monotonic``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Union

from repro.obs.metrics import MetricsRegistry
from repro.obs.observer import NULL_OBSERVER, NullObserver, Observer
from repro.obs.trace import Tracer


@dataclass(frozen=True)
class ObsConfig:
    # when set, the owning engine's drain() writes the Chrome
    # trace-event JSON artifact here (load it at https://ui.perfetto.dev)
    trace_path: Optional[str] = None
    # when set, drain() writes the metrics artifact here; a ".prom" /
    # ".txt" suffix selects Prometheus text exposition, else JSON
    metrics_path: Optional[str] = None
    # record in memory without a dump path (benchmarks/tests read the
    # artifact / snapshot off the observer directly)
    trace: bool = False
    metrics: bool = False
    # injectable monotonic clock (seconds); None = time.monotonic
    clock: Optional[Callable[[], float]] = None
    # trace process_name for pid 0 (shard views name their own pids)
    process_name: str = "serve"

    @property
    def enabled(self) -> bool:
        return bool(self.trace_path or self.metrics_path
                    or self.trace or self.metrics)

    @property
    def trace_on(self) -> bool:
        return bool(self.trace or self.trace_path)

    @property
    def metrics_on(self) -> bool:
        return bool(self.metrics or self.metrics_path)

    def resolve(self) -> Union[Observer, NullObserver]:
        if not self.enabled:
            return NULL_OBSERVER
        return Observer(
            tracer=Tracer() if self.trace_on else None,
            metrics=MetricsRegistry() if self.metrics_on else None,
            clock=self.clock, process_name=self.process_name)


def resolve_obs(scfg: Any) -> Union[Observer, NullObserver]:
    """Resolve an engine's observer from its ``ServeConfig`` paths
    (the engine-owned construction site; an explicitly injected
    observer — e.g. a shard view — always wins upstream)."""
    return ObsConfig(trace_path=scfg.trace_path,
                     metrics_path=scfg.metrics_path).resolve()
