"""Sharding: logical-axis rules -> NamedShardings over the production mesh."""
from repro.sharding.rules import (  # noqa: F401
    ShardingRules,
    activation_rules,
    cache_rules,
    param_rules,
    replicated,
    spec_for,
    tree_shardings,
)
