"""Logical-axis -> mesh-axis rules, with divisibility-safe fallback.

One place decides how every tensor in the system is laid out:

- **Params**: FSDP + TP.  ``embed`` (the residual-stream dim, present in
  every weight) shards over the data axes — fully-sharded parameters and
  optimizer state, gathered per-layer inside the scan (GSPMD inserts the
  all-gathers).  The "tensor" dims (``heads``/``ff``/``vocab``/``experts``/
  ``state``) shard over the model axis — Megatron-style TP with expert
  parallelism folded in.
- **Activations**: ``batch`` over the data axes, ``heads``/``vocab`` over
  model, residual dim replicated.
- **KV caches**: ``batch`` over data; the *model-axis* placement is
  decided per-workload by the paper's policy (sequence vs. head sharding
  — see ``serving/decode_step.py``).

A dim is sharded only if its size divides the axis size, and each mesh
axis is used at most once per tensor (first dim in axis order wins) —
otherwise the dim falls back to replicated.  This keeps every assigned
architecture lowerable on the production mesh without per-arch rules.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any
MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes)."""
    rules: Dict[str, MeshAxes] = field(default_factory=dict)

    def lookup(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        m = self.rules.get(logical)
        if m is None:
            return ()
        return (m,) if isinstance(m, str) else tuple(m)


def _axes_in_mesh(mesh: Mesh, axes: Sequence[str]) -> Tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.axis_names)


def spec_for(shape: Tuple[int, ...], logical: Tuple[Optional[str], ...],
             rules: ShardingRules, mesh: Mesh) -> P:
    """Divisibility- and conflict-safe PartitionSpec for one tensor."""
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        want = _axes_in_mesh(mesh, rules.lookup(name))
        # drop axes already used by an earlier dim of this tensor
        want = tuple(a for a in want if a not in used)
        # greedy prefix that divides the dim size
        chosen: Tuple[str, ...] = ()
        size = 1
        for a in want:
            nsz = size * mesh.shape[a]
            if dim % nsz == 0:
                chosen += (a,)
                size = nsz
            else:
                break
        used.update(chosen)
        if len(chosen) == 0:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(mesh: Mesh, shapes: Pytree, logical: Pytree,
                   rules: ShardingRules) -> Pytree:
    """Pytree of NamedShardings. `shapes` leaves: ShapeDtypeStruct/arrays."""
    def one(leaf, axes):
        return NamedSharding(mesh, spec_for(tuple(leaf.shape), axes, rules,
                                            mesh))
    # `logical` leaves are tuples — zip the two trees manually
    flat_s, treedef = jax.tree_util.tree_flatten(shapes)
    flat_a = treedef.flatten_up_to(logical)
    return jax.tree_util.tree_unflatten(
        treedef, [one(s, a) for s, a in zip(flat_s, flat_a)])


# ---------------------------------------------------------------------------
# Standard rule sets
# ---------------------------------------------------------------------------


def param_rules() -> ShardingRules:
    return ShardingRules({
        "embed": ("pod", "data"),          # FSDP
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "experts": "model",
        "state": "model",
        # layers / head_dim / seq: replicated
    })


def serve_param_rules() -> ShardingRules:
    """Inference layout: TP on model, no FSDP (no per-step all-gathers).

    Expert weights additionally spread over the data axes — big MoE
    checkpoints (Qwen3-235B) exceed one chip's HBM under TP-16 alone.
    (Historically defined in ``serving/decode_step.py``; lives here with
    the other rule sets so the mesh-native engine and the frozen dry-run
    builder share one definition.)
    """
    return ShardingRules({
        "embed": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "state": "model",
        "experts": ("pod", "data", "model"),
    })


def activation_rules() -> ShardingRules:
    return ShardingRules({
        "batch": ("pod", "data"),
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "experts": "model",
        "state": "model",
    })


def cache_rules(seq_split: bool) -> ShardingRules:
    """KV-cache rules; `seq_split` is the paper's mesh-level decision."""
    base = {
        "batch": ("pod", "data"),
        "kv_heads": None if seq_split else "model",
        "heads": "model",                  # ssm state heads
        "state": "model",
        "seq": "model" if seq_split else None,
    }
    return ShardingRules(base)


def batch_spec(mesh: Mesh, batch_dim_first: bool = True) -> NamedSharding:
    axes = _axes_in_mesh(mesh, ("pod", "data"))
    return NamedSharding(mesh, P(axes if axes else None))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
