"""Activation-sharding context: mesh-agnostic models, explicit layouts.

Model code calls ``shard_activation(x, ("batch", None, None))`` at layout
anchor points (post-embedding, scan carries, logits).  Outside any
context this is a no-op, so models run untouched on a single device; the
train/prefill/serve builders enter the context inside their jitted step
bodies, binding the production mesh + rules.

Without these anchors GSPMD loses the batch sharding at the embedding
gather (the vocab-sharded table wins the propagation fight) and every
activation in the layer scan replicates over the data axes — observed as
37 GiB/device all-gathers in the first dry-run of this repo.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.rules import ShardingRules, activation_rules, spec_for

_STACK: list = []


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh],
                    rules: Optional[ShardingRules] = None):
    """Bind the activation layout anchors to ``mesh``.

    ``mesh=None`` is a no-op context: mesh-optional callers (the serving
    engine runs the same jitted-impl bodies single-device and on a shard
    sub-mesh) wrap unconditionally instead of branching at every site.
    """
    if mesh is None:
        yield
        return
    _STACK.append((mesh, rules or activation_rules()))
    try:
        yield
    finally:
        _STACK.pop()


def shard_activation(x: jax.Array,
                     logical: Tuple[Optional[str], ...]) -> jax.Array:
    """Constrain x to the active mesh's layout for these logical axes."""
    if not _STACK:
        return x
    mesh, rules = _STACK[-1]
    spec = spec_for(tuple(x.shape), logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh() -> Optional[Mesh]:
    """The active activation mesh, or None outside any context."""
    return _STACK[-1][0] if _STACK else None
