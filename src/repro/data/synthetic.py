"""Deterministic synthetic LM data pipeline: host-sharded, resumable.

Every batch is a pure function of ``(seed, step, host_id)`` — no state to
checkpoint beyond the step counter, so restart/elastic-restore recovery
is "skip to step N" (see ``fault/``).  The generator models a crude
n-gram-ish structure (token t+1 depends on t) so tiny models can visibly
learn it in the examples/integration tests; labels mirror the tokens
(next-token prediction does the shift in the loss).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    structure: float = 0.8        # P(next token = f(current)) vs uniform


class SyntheticLM:
    """Stateless batch source; ``batch_at(step)`` is the whole API."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0, \
            "global batch must divide across hosts"
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.num_hosts
        # fixed random successor table: the "grammar" tiny models learn
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(0, cfg.vocab_size,
                                  size=cfg.vocab_size).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + step) * 4096 + c.host_id)
        B, L = self.host_batch, c.seq_len
        toks = np.empty((B, L), np.int32)
        toks[:, 0] = rng.integers(0, c.vocab_size, size=B)
        structured = rng.random((B, L - 1)) < c.structure
        noise = rng.integers(0, c.vocab_size, size=(B, L - 1))
        for i in range(1, L):
            toks[:, i] = np.where(structured[:, i - 1],
                                  self._succ[toks[:, i - 1]],
                                  noise[:, i - 1])
        return {"tokens": toks, "labels": toks.copy()}

    def iter_from(self, step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1
