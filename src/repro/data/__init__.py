"""Data pipelines (deterministic synthetic LM)."""
from repro.data.synthetic import DataConfig, SyntheticLM  # noqa: F401
