"""Legacy shim over ``repro.plan`` — the paper's metadata-enabled path.

The planning API moved to the first-class ``repro.plan`` package
(AttentionSpec -> Planner -> LaunchPlan -> PlanCache); this module keeps
the original FA3-style entry points importable:

- :class:`SchedulerMetadata` is now an alias of
  :class:`~repro.plan.LaunchPlan` (a strict superset of the old frozen
  plan: same ``workload`` / ``num_splits`` / ``pack_gqa`` / ``policy`` /
  ``num_cores`` surface, plus impl / block_k / bucket / mesh fields).
- :func:`get_scheduler_metadata` mirrors FA3 / vLLM's entry point and
  delegates to a default :class:`~repro.plan.Planner` behind a bounded
  process-wide :class:`~repro.plan.PlanCache` (which replaced the old
  unbounded ``functools.lru_cache``).

New code should construct a ``Planner`` directly — see README
"Architecture" for the migration map.
"""
from __future__ import annotations

from typing import Optional

from repro.core.split_policy import DEFAULT_NUM_CORES, get_policy
from repro.plan import AttentionSpec, LaunchPlan, PlanCache, Planner
from repro.plan import bucket_seqlen  # noqa: F401  (canonical home moved)

# Deprecated alias: the frozen plan type is LaunchPlan now.
SchedulerMetadata = LaunchPlan

# Process-wide plan cache (bounded, unlike the lru_cache it replaced;
# launch traces off — only hit/miss counters matter here).
_PLAN_CACHE = PlanCache(capacity=4096, track_launches=False)


def get_scheduler_metadata(
    batch: int,
    seqlen_q: int,
    seqlen_k: int,
    num_heads_q: int,
    num_heads_kv: int,
    head_dim: int = 128,
    *,
    policy: str = "paper",
    num_cores: int = DEFAULT_NUM_CORES,
    num_splits_override: Optional[int] = None,
    pack_gqa: Optional[bool] = None,
) -> LaunchPlan:
    """Compute (and cache) the launch plan for a decode shape.

    ``num_splits_override`` mirrors FA3's explicit ``num_splits`` argument:
    benchmarks use it to force a split count (e.g. the Fig. 3 U-curve sweep)
    while production callers leave it ``None`` and get the policy's choice.
    """
    fn = get_policy(policy)
    if getattr(fn, "needs_table", False):
        # table-backed policies cannot serve the inline-evaluation path:
        # the SplitTable rides Planner instances, and this entry point is
        # reached only from trace-time dispatch (no planner in hand) —
        # e.g. a cross-attention launch opting out of a measured engine's
        # ambient plan.  Resolve to the backend's declared analytic
        # fallback, exactly what the table does for uncovered shapes.
        policy = getattr(fn, "fallback", "paper")
    key = (batch, seqlen_q, seqlen_k, num_heads_q, num_heads_kv, head_dim,
           policy, num_cores, num_splits_override, pack_gqa)

    def build() -> LaunchPlan:
        spec = AttentionSpec("decode", batch, seqlen_q, seqlen_k,
                             num_heads_q, num_heads_kv, head_dim)
        return Planner(policy=policy, num_cores=num_cores,
                       num_splits_override=num_splits_override,
                       pack_gqa=pack_gqa).plan(spec)

    return _PLAN_CACHE.get_or_build(key, build)


def metadata_cache_info():
    """Hit/miss counters of the process-wide plan cache (observability)."""
    return _PLAN_CACHE.cache_info()
