"""Precomputed scheduler metadata — the paper's metadata-enabled path.

Paper SS5: the 21-24% wins apply to deployments that *precompute* scheduling
metadata (``get_scheduler_metadata()`` in FA3 / vLLM) and pass explicit
``num_splits`` at launch, instead of re-running the heuristic inside the
kernel dispatch.  This module is that API for our stack: the serving engine
calls :func:`get_scheduler_metadata` once per (batch-shape, cache-length
bucket) and hands the frozen plan to the attention op, keeping the policy
out of the hot loop (and out of the jitted graph — the split count is a
static Python int, so XLA specializes the kernel grid on it).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Tuple

from repro.core.split_policy import (
    DEFAULT_NUM_CORES,
    DecodeWorkload,
    choose_num_splits,
)


@dataclass(frozen=True)
class SchedulerMetadata:
    """Frozen launch plan for one decode-attention shape."""
    workload: DecodeWorkload
    num_splits: int
    pack_gqa: bool
    policy: str
    num_cores: int

    @property
    def uses_split(self) -> bool:
        return self.num_splits > 1


@lru_cache(maxsize=4096)
def get_scheduler_metadata(
    batch: int,
    seqlen_q: int,
    seqlen_k: int,
    num_heads_q: int,
    num_heads_kv: int,
    head_dim: int = 128,
    *,
    policy: str = "paper",
    num_cores: int = DEFAULT_NUM_CORES,
    num_splits_override: Optional[int] = None,
    pack_gqa: Optional[bool] = None,
) -> SchedulerMetadata:
    """Compute (and cache) the launch plan for a decode shape.

    ``num_splits_override`` mirrors FA3's explicit ``num_splits`` argument:
    benchmarks use it to force a split count (e.g. the Fig. 3 U-curve sweep)
    while production callers leave it ``None`` and get the policy's choice.
    """
    w = DecodeWorkload(batch, seqlen_q, seqlen_k, num_heads_q, num_heads_kv,
                       head_dim)
    if num_splits_override is not None:
        s = max(1, min(int(num_splits_override), w.num_n_blocks))
    else:
        s = choose_num_splits(w, policy=policy, num_cores=num_cores)
    if pack_gqa is None:
        pack_gqa = num_heads_q > num_heads_kv
    return SchedulerMetadata(w, s, pack_gqa, policy, num_cores)


def metadata_cache_info():
    """Hit/miss counters of the process-wide metadata cache (observability)."""
    return get_scheduler_metadata.cache_info()


def bucket_seqlen(seqlen_k: int, bucket: int = 128) -> int:
    """Round a cache length up to its block bucket so metadata cache hits.

    The serving engine quantizes L_K to the KV block width: the policy's
    decision only depends on ``num_n_blocks``, so this is lossless.
    """
    return ((max(1, seqlen_k) + bucket - 1) // bucket) * bucket
