"""Evolutionary discovery of split heuristics (paper SS3, OpenEvolve analogue).

The paper found the FA3 guard flaw by letting an LLM-guided evolutionary
search rewrite the Python-level scheduling heuristic in-the-loop on a live
H100.  We reproduce the *method* with a plain (no-LLM) evolutionary search:

- **Genome**: a bucketed policy table — for each (L_K bucket, H_KV bucket,
  B bucket): ``num_splits``; plus global ``pack_gqa`` and ``sm_margin``.
  This is exactly the search space the paper exposed (SS3.1).
- **Fitness**: total modeled TPOT over a target workload set (the paper's
  "short-prompt chat" shapes), evaluated on the occupancy cost model —
  our stand-in for their live-GPU microbenchmark loop.
- **Operators**: tournament selection, per-gene mutation, uniform
  crossover; invalid candidates (split > nblk) are clamped, mirroring the
  paper's subprocess evaluator rejecting invalid variants.

``examples/evolve_heuristic.py`` runs this and prints the evolved table —
re-discovering the paper's observation that low-tile short-context buckets
want aggressive splits (they evolved 12-16) while saturated buckets stay
at 1.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from repro.core.occupancy import TPU_V5E, HardwareModel, modeled_latency_us
from repro.core.split_policy import DecodeWorkload

# Buckets mirror the paper's sweep axes.
LK_BUCKETS: Tuple[int, ...] = (128, 256, 384, 512, 1024, 2048, 4096, 8192)
HKV_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 32)
B_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8)

GeneKey = Tuple[int, int, int]           # (lk_bucket, hkv, batch)


def _bucket(value: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if value <= b:
            return b
    return buckets[-1]


@dataclass
class Genome:
    splits: Dict[GeneKey, int] = field(default_factory=dict)
    pack_gqa: bool = True
    sm_margin: int = 0

    def num_splits_for(self, w: DecodeWorkload) -> int:
        key = (_bucket(w.seqlen_k, LK_BUCKETS),
               _bucket(w.num_heads_kv, HKV_BUCKETS),
               _bucket(w.batch, B_BUCKETS))
        s = self.splits.get(key, 1)
        return max(1, min(s, w.num_n_blocks))   # clamp invalid candidates


def default_workload_set(head_dim: int = 128,
                         num_heads_q: int = 8) -> List[DecodeWorkload]:
    """The paper's target scenario: short-prompt single-batch chat decode,
    plus saturated shapes so evolution is penalized for regressions."""
    ws = []
    for lk in LK_BUCKETS:
        for hkv in HKV_BUCKETS:
            for b in B_BUCKETS:
                hq = max(num_heads_q, hkv)
                ws.append(DecodeWorkload(b, 1, lk, hq, hkv, head_dim))
    return ws


def fitness(g: Genome, workloads: Sequence[DecodeWorkload],
            num_cores: int, hw: HardwareModel = TPU_V5E) -> float:
    """Negative total modeled latency (higher is better)."""
    total = 0.0
    for w in workloads:
        total += modeled_latency_us(
            w, g.num_splits_for(w), num_cores=num_cores, hw=hw,
            pack_gqa=g.pack_gqa, sm_margin=g.sm_margin)
    return -total


def _mutate(g: Genome, rng: random.Random, rate: float = 0.25) -> Genome:
    child = Genome(dict(g.splits), g.pack_gqa, g.sm_margin)
    for key in list(child.splits.keys()):
        if rng.random() < rate:
            step = rng.choice([-4, -2, -1, 1, 2, 4, 8])
            child.splits[key] = max(1, min(64, child.splits[key] + step))
    if rng.random() < 0.05:
        child.pack_gqa = not child.pack_gqa
    if rng.random() < 0.05:
        child.sm_margin = max(0, min(4, child.sm_margin + rng.choice([-1, 1])))
    return child


def _crossover(a: Genome, b: Genome, rng: random.Random) -> Genome:
    child = Genome({}, a.pack_gqa if rng.random() < 0.5 else b.pack_gqa,
                   a.sm_margin if rng.random() < 0.5 else b.sm_margin)
    for key in a.splits:
        child.splits[key] = (a.splits if rng.random() < 0.5 else b.splits)[key]
    return child


@dataclass
class EvolveResult:
    best: Genome
    best_fitness: float
    history: List[float]                 # best fitness per generation
    baseline_fitness: float              # all-ones genome (the static guard)


def evolve(
    *,
    num_cores: int,
    hw: HardwareModel = TPU_V5E,
    generations: int = 40,
    population: int = 32,
    seed: int = 0,
    workloads: Sequence[DecodeWorkload] | None = None,
) -> EvolveResult:
    rng = random.Random(seed)
    ws = list(workloads) if workloads is not None else default_workload_set()

    keys = [(lk, hkv, b) for lk in LK_BUCKETS for hkv in HKV_BUCKETS
            for b in B_BUCKETS]
    baseline = Genome({k: 1 for k in keys})          # the static guard: never split
    base_fit = fitness(baseline, ws, num_cores, hw)

    pop = [baseline]
    for _ in range(population - 1):
        g = Genome({k: rng.choice([1, 1, 2, 4, 8, 16]) for k in keys})
        pop.append(g)

    history: List[float] = []
    for _gen in range(generations):
        scored = sorted(((fitness(g, ws, num_cores, hw), i, g)
                         for i, g in enumerate(pop)), reverse=True)
        history.append(scored[0][0])
        elite = [g for _, _, g in scored[: max(2, population // 8)]]
        nxt = list(elite)
        while len(nxt) < population:
            # tournament selection
            a = max(rng.sample(scored, 3))[2]
            b = max(rng.sample(scored, 3))[2]
            nxt.append(_mutate(_crossover(a, b, rng), rng))
        pop = nxt

    final = sorted(((fitness(g, ws, num_cores, hw), i, g)
                    for i, g in enumerate(pop)), reverse=True)
    best_fit, _, best = final[0]
    return EvolveResult(best, best_fit, history, base_fit)


def summarize_low_tile_genes(g: Genome, num_cores: int) -> Dict[GeneKey, int]:
    """The genes the paper's analysis dissected: starved buckets (tiles<cores)."""
    out = {}
    for (lk, hkv, b), s in sorted(g.splits.items()):
        if b * hkv < num_cores and s > 1:
            out[(lk, hkv, b)] = s
    return out
