"""Analytic occupancy / latency model for split-KV decode attention.

The container is CPU-only, so the paper's CUDA-graph A/B wall-clock cannot
be reproduced on real hardware.  This module is the measurement surrogate:
a three-regime cost model of a split-KV decode kernel on a machine with
``num_cores`` parallel execution slots (H100: 132 SMs; TPU: chips on the
sharding axis, or pipeline slots within a chip).

Regimes (exactly the ones the paper's Table 1 / Fig. 3 exhibit):

1. **Launch-bound** (tiny L_K): fixed launch overhead dominates; splitting
   cannot help -> flat rows at L_K <= 384.
2. **Latency-bound, starved grid** (few tiles, moderate L_K): a single
   work tile walks its KV blocks *sequentially* with memory latency
   exposed; splitting converts chain length into parallel width -> the
   paper's 1.21-1.24x bucket.
3. **Bandwidth-bound, saturated grid** (many tiles or huge L_K): all
   cores busy; splitting only adds combine overhead -> the efficiency
   loop / guards keep s=1, no regression.

Two hardware constant sets:

- ``TPU_V5E``: native target (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI).
- ``H100_SXM``: used by ``benchmarks/table1_ab.py`` to check the model
  reproduces the paper's measured Table 1 within a few percent — the
  calibration evidence that the model's *structure* is right.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.split_policy import KV_BLOCK, DecodeWorkload


@dataclass(frozen=True)
class HardwareModel:
    name: str
    num_cores: int              # parallel execution slots for one launch
    mxu_flops: float            # peak FLOP/s (bf16)
    hbm_bw: float               # B/s
    ici_bw: float               # B/s per link (mesh-level combine)
    launch_us: float            # fixed kernel dispatch overhead
    block_latency_us: float     # exposed latency per sequential KV block
    tile_fixed_us: float        # per-grid-cell setup (semaphores, DMA start)
    combine_fixed_us: float     # split-combine kernel fixed cost
    vmem_bytes: int = 64 * 2**20


TPU_V5E = HardwareModel(
    name="tpu_v5e",
    num_cores=8,                # default: v5e-8 serving slice (TP=8, the
                                # paper's Llama-70B deployment analogue)
    mxu_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    launch_us=2.0,
    block_latency_us=1.0,
    tile_fixed_us=0.05,
    combine_fixed_us=0.3,
    vmem_bytes=128 * 2**20,
)

# Calibrated against paper Table 1 (see benchmarks/table1_ab.py):
# L_K=128 row (9.56us, one block) pins launch_us + block_latency;
# the L_K=128->512 slope pins block_latency_us.
H100_SXM = HardwareModel(
    name="h100_sxm",
    num_cores=132,
    mxu_flops=989e12,
    hbm_bw=3.35e12,
    ici_bw=450e9,
    launch_us=8.40,
    block_latency_us=1.17,
    tile_fixed_us=0.02,
    combine_fixed_us=0.35,
    vmem_bytes=228 * 1024,      # SMEM per SM; unused in the latency terms
)


def _per_tile_kv_bytes(w: DecodeWorkload, num_splits: int) -> int:
    blocks = math.ceil(w.num_n_blocks / num_splits)
    return blocks * KV_BLOCK * 2 * w.head_dim * w.dtype_bytes  # K and V


def modeled_latency_us(
    w: DecodeWorkload,
    num_splits: int,
    num_cores: int | None = None,
    hw: HardwareModel = TPU_V5E,
    pack_gqa: bool = True,
    sm_margin: int = 0,
) -> float:
    """Modeled kernel latency (microseconds) for a given split count.

    ``sm_margin`` reserves cores for the combine stage (paper SS3.1 search
    space); on TPU it survives only here, as a cost-model parameter.
    """
    cores = (num_cores if num_cores is not None else hw.num_cores) - sm_margin
    cores = max(1, cores)
    s = max(1, min(num_splits, w.num_n_blocks))

    group = max(1, w.num_heads_q // max(1, w.num_heads_kv))
    tiles = w.tiles(s)
    waves = math.ceil(tiles / cores)
    blocks_per_split = math.ceil(w.num_n_blocks / s)

    # --- per-block service time -------------------------------------------
    block_bytes = KV_BLOCK * 2 * w.head_dim * w.dtype_bytes
    concurrency = min(tiles, cores)            # tiles sharing HBM bandwidth
    bw_block_us = block_bytes * concurrency / hw.hbm_bw * 1e6
    # latency hiding: with >=2 tiles resident per core the pipeline hides
    # most of the exposed latency (producer/consumer overlap).
    resident = tiles / cores
    latency_us = hw.block_latency_us / min(4.0, max(1.0, resident))
    block_us = max(latency_us, bw_block_us)

    # --- compute term (MXU): GQA-packed rides one matmul ------------------
    flops_per_block = 2 * 2 * (w.seqlen_q * group) * KV_BLOCK * w.head_dim
    compute_block_us = flops_per_block / hw.mxu_flops * 1e6
    block_us = max(block_us, compute_block_us)

    # pack_gqa=False issues per-head Q loads: extra per-tile fixed cost.
    tile_fixed = hw.tile_fixed_us * (1.0 if pack_gqa else 1.0 + 0.25 * (group - 1))

    t_main = waves * (blocks_per_split * block_us + tile_fixed)

    # --- combine stage ------------------------------------------------------
    t_combine = 0.0
    if s > 1:
        # write s partials (out + lse) then one reduction pass over them
        partial_bytes = s * w.batch * w.num_heads_q * (w.head_dim + 1) * 4 * 2
        t_combine = hw.combine_fixed_us + partial_bytes / hw.hbm_bw * 1e6

    return hw.launch_us + t_main + t_combine


def modeled_speedup(w: DecodeWorkload, s_base: int, s_new: int,
                    num_cores: int | None = None,
                    hw: HardwareModel = TPU_V5E) -> float:
    t0 = modeled_latency_us(w, s_base, num_cores=num_cores, hw=hw)
    t1 = modeled_latency_us(w, s_new, num_cores=num_cores, hw=hw)
    return t0 / t1


def occupancy_fraction(w: DecodeWorkload, num_splits: int,
                       num_cores: int | None = None,
                       hw: HardwareModel = TPU_V5E) -> float:
    """Fraction of cores holding at least one tile (the paper's ~6% story)."""
    cores = num_cores if num_cores is not None else hw.num_cores
    return min(1.0, w.tiles(num_splits) / cores)
