"""Core: the paper's contribution — sequence-aware split-KV scheduling.

Re-exports are lazy (PEP 562): ``repro.core.scheduler_metadata`` is a
shim over ``repro.plan``, whose modules import
``repro.core.split_policy`` — eager re-exports here would close an
import cycle.  Everything the old eager ``__init__`` exposed is still
importable from this package.
"""
_SUBMODULE_EXPORTS = {
    "repro.core.occupancy": (
        "H100_SXM", "HardwareModel", "TPU_V5E", "modeled_latency_us",
        "modeled_speedup", "occupancy_fraction"),
    "repro.core.scheduler_metadata": (
        "SchedulerMetadata", "bucket_seqlen", "get_scheduler_metadata",
        "metadata_cache_info"),
    "repro.core.split_policy": (
        "DEFAULT_NUM_CORES", "KV_BLOCK", "DecodeWorkload", "POLICIES",
        "analytic_policies", "available_policies", "choose_mesh_splits",
        "choose_num_splits", "fa3_baseline", "get_policy", "measured",
        "paper_policy", "tpu_adaptive"),
}

__all__ = sorted(n for names in _SUBMODULE_EXPORTS.values() for n in names)


def __getattr__(name):
    import importlib
    for mod, names in _SUBMODULE_EXPORTS.items():
        if name in names:
            return getattr(importlib.import_module(mod), name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
