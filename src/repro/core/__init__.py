"""Core: the paper's contribution — sequence-aware split-KV scheduling."""
from repro.core.occupancy import (  # noqa: F401
    H100_SXM,
    HardwareModel,
    TPU_V5E,
    modeled_latency_us,
    modeled_speedup,
    occupancy_fraction,
)
from repro.core.scheduler_metadata import (  # noqa: F401
    SchedulerMetadata,
    bucket_seqlen,
    get_scheduler_metadata,
    metadata_cache_info,
)
from repro.core.split_policy import (  # noqa: F401
    DEFAULT_NUM_CORES,
    KV_BLOCK,
    DecodeWorkload,
    POLICIES,
    choose_mesh_splits,
    choose_num_splits,
    fa3_baseline,
    get_policy,
    paper_policy,
    tpu_adaptive,
)
