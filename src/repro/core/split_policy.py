"""The paper's contribution: sequence-aware split-KV scheduling policies.

Three selectable policies (A/B-able everywhere in the framework):

``fa3_baseline``
    Faithful port of the *flawed* upstream FlashAttention-3 heuristic
    (``heuristics.h`` pre-patch): an unconditional guard returns
    ``num_splits = 1`` whenever ``num_n_blocks <= 4`` (i.e. L_K <= 512 with
    the 128-wide KV block), no matter how starved the grid is.  Longer
    contexts go through the upstream wave-efficiency loop.

``paper``
    Faithful port of the paper's conservative C++ policy (Fig. 2):

    - Guard 1: ``nblk <= 3``                       -> s = 1   (unchanged)
    - Guard 2: ``nblk == 4 and tiles >= 4``        -> s = 1   (saturated)
    - Override: ``nblk == 4 and tiles < 4``        -> s = 3   (low-tile)
    - longer contexts -> upstream efficiency loop            (unchanged)

``tpu_adaptive``
    Beyond-paper generalization (paper SS4.1 names this future work):
    choose ``argmin`` of the analytic occupancy cost model over all
    feasible split counts, for *every* L_K — i.e. the policy the evolved
    Python heuristics were approximating (s=12/16 for very short low-tile
    shapes), made principled.  Property-tested to never regress the
    modeled latency vs. ``fa3_baseline``.

``measured``
    The ``repro.tune`` backend (paper SS4.1's "replace the model with
    hardware measurement"): decide from a calibrated
    :class:`~repro.tune.SplitTable` of per-shape measured (or, in CI,
    modeled) candidate latencies.  Table-backed — the table is injected
    at :class:`~repro.plan.Planner` construction; shapes the table's
    grid does not cover fall back to ``paper`` explicitly (and are
    counted).  Marked ``needs_table`` below so analytic consumers (the
    golden decision table, property sweeps) can enumerate
    :func:`analytic_policies` instead.

All policies operate on a :class:`DecodeWorkload` so they are independent of
where they run (Pallas kernel launch, XLA decode path, mesh-level sequence
sharding, or the benchmark cost model).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional

# KV block width used by the kernel's BlockSpec (and by upstream FA3's
# num_n_blocks computation). 128 matches both FA3 Hopper's kBlockN for
# decode head_dim=128 and the TPU lane width.
KV_BLOCK = 128

# Parallel grid slots per TPU chip the scheduler targets.  A v5e chip has a
# single TensorCore, but the Pallas pipeline keeps multiple grid cells in
# flight (double-buffered DMA + compute overlap); at the *mesh* level the
# same policy is evaluated with num_cores = chips on the sharding axis.
DEFAULT_NUM_CORES = 8
MAX_SPLITS = 128

# Bytes per KV-cache element, by dtype *name*.  ``dtype_bytes`` is what the
# occupancy cost model streams; the NAME is what keys tuned-table families —
# int8 and fp8 both move 1 byte/element but run different dequant kernels, so
# a measured int8 cell must never answer for an fp8 workload.
KV_DTYPES: Dict[str, int] = {
    "bfloat16": 2,
    "float32": 4,
    "int8": 1,
    "fp8": 1,        # float8_e4m3fn storage, f32 scales
}

# Legacy inference for workloads constructed before kv_dtype existed (and
# for hand-built DecodeWorkloads in tests/benchmarks): bytes -> canonical name.
_BYTES_TO_NAME: Dict[int, str] = {2: "bfloat16", 4: "float32", 1: "int8"}


@dataclass(frozen=True)
class DecodeWorkload:
    """Shape tuple of one decode-attention kernel launch.

    Mirrors the paper's shape tuple (Batch, L_Q, L_K, H_Q, H_KV, D).
    """
    batch: int
    seqlen_q: int          # 1 for pure decode
    seqlen_k: int          # KV cache length (L_K)
    num_heads_q: int
    num_heads_kv: int
    head_dim: int = 128
    dtype_bytes: int = 2   # bf16
    # KV dtype NAME (a KV_DTYPES key).  None = infer from dtype_bytes —
    # normalized in __post_init__ so legacy call sites compare equal to
    # name-passing ones.  The name distinguishes same-width families:
    # fp8 must not inherit int8 tune cells.
    kv_dtype: Optional[str] = None

    def __post_init__(self) -> None:
        if self.kv_dtype is None:
            object.__setattr__(self, "kv_dtype",
                               _BYTES_TO_NAME.get(self.dtype_bytes))
            return
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r}; "
                f"known: {sorted(KV_DTYPES)}")
        if KV_DTYPES[self.kv_dtype] != self.dtype_bytes:
            raise ValueError(
                f"kv_dtype={self.kv_dtype!r} is "
                f"{KV_DTYPES[self.kv_dtype]} byte(s)/element but "
                f"dtype_bytes={self.dtype_bytes}; pass matching values "
                f"(e.g. dtype_bytes=KV_DTYPES[kv_dtype])")

    @property
    def kv_dtype_name(self) -> str:
        """Canonical dtype name for family keying (never None for any
        registered byte width)."""
        if self.kv_dtype is not None:
            return self.kv_dtype
        return f"bytes{self.dtype_bytes}"

    @property
    def num_n_blocks(self) -> int:
        """Sequence blocks: the ``nblk`` of the paper."""
        return max(1, math.ceil(self.seqlen_k / KV_BLOCK))

    @property
    def num_m_blocks(self) -> int:
        """M-blocks per (batch, kv-head): 1 for decode (L_Q = 1 rides MXU M)."""
        # GQA-packed: the L_Q * group queries share one M block up to 128 rows.
        group = max(1, self.num_heads_q // max(1, self.num_heads_kv))
        return max(1, math.ceil(self.seqlen_q * group / 128))

    @property
    def total_mblocks(self) -> int:
        """Aggregate work tiles before splitting (paper: Batch x H_KV for decode)."""
        return self.batch * self.num_heads_kv * self.num_m_blocks

    def tiles(self, num_splits: int) -> int:
        return self.total_mblocks * num_splits


# ---------------------------------------------------------------------------
# Upstream efficiency loop (shared by fa3_baseline and paper for long L_K)
# ---------------------------------------------------------------------------


def _upstream_efficiency_loop(w: DecodeWorkload, num_cores: int,
                              max_splits: int = MAX_SPLITS) -> int:
    """Port of FA3's ``num_splits_heuristic``: maximize wave efficiency.

    Chooses the smallest ``s`` whose "wave efficiency" (how evenly
    ``tiles(s)`` fills multiples of the SM/core count) is within 85% of the
    best achievable, preferring smaller splits to bound combine overhead.
    """
    tiles_1 = w.tiles(1)
    if tiles_1 >= 0.8 * num_cores:
        # grid already (nearly) fills the machine: never split.
        return 1
    max_splits = min(max_splits, w.num_n_blocks, num_cores)
    if max_splits <= 1:
        return 1

    def efficiency(s: int) -> float:
        n_waves = w.tiles(s) / num_cores
        return n_waves / math.ceil(n_waves) if n_waves > 0 else 0.0

    best_eff = max(efficiency(s) for s in range(1, max_splits + 1))
    for s in range(1, max_splits + 1):
        # skip split counts that do not reduce the per-split block count
        # (identical work partitioning to s-1 -> pure overhead).
        if s > 1 and math.ceil(w.num_n_blocks / s) == math.ceil(w.num_n_blocks / (s - 1)):
            continue
        if efficiency(s) >= 0.85 * best_eff:
            return s
    return 1


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


def fa3_baseline(w: DecodeWorkload, num_cores: int = DEFAULT_NUM_CORES) -> int:
    """The flawed upstream heuristic: static short-sequence guard.

    ``heuristics.h`` pre-patch: ``if (num_n_blocks <= 4) return 1;`` —
    sequence length alone decides, tile count is never consulted.
    """
    if w.num_n_blocks <= 4:
        return 1
    return _upstream_efficiency_loop(w, num_cores)


def paper_policy(w: DecodeWorkload, num_cores: int = DEFAULT_NUM_CORES) -> int:
    """Paper Fig. 2: conservative sequence-aware policy, bit-exact.

    // Guard 1: L_K <= 384 (nblk <= 3) - leave shorter contexts unchanged
    if (num_n_blocks <= 3) { return 1; }
    // Guard 2: nblk = 4 boundary bucket with enough tiles
    if (num_n_blocks <= 4 && total_mblocks >= 4) { return 1; }
    // Low-tile boundary case
    if (num_n_blocks == 4 && total_mblocks < 4) { return 3; }
    // longer contexts: existing efficiency loop (unchanged)
    """
    if w.num_n_blocks <= 3:
        return 1
    if w.num_n_blocks <= 4 and w.total_mblocks >= 4:
        return 1
    if w.num_n_blocks == 4 and w.total_mblocks < 4:
        return 3
    return _upstream_efficiency_loop(w, num_cores)


def tpu_adaptive(w: DecodeWorkload, num_cores: int = DEFAULT_NUM_CORES) -> int:
    """Beyond-paper: occupancy-cost-model argmin over all feasible splits.

    Generalizes the paper's boundary-bucket override to every L_K (their
    SS4.1 future work): split whenever the machine is starved AND the
    combine/partial-HBM overhead is amortized, as judged by the analytic
    cost model.  Ties break toward the smallest split (the paper's
    "smallest split entering the low-latency regime" safeguard).
    """
    from repro.core.occupancy import modeled_latency_us  # local: avoid cycle
    max_s = min(w.num_n_blocks, num_cores, MAX_SPLITS)
    if max_s <= 1 or w.tiles(1) >= num_cores:
        return 1
    best_s, best_t = 1, modeled_latency_us(w, 1, num_cores=num_cores)
    for s in range(2, max_s + 1):
        if math.ceil(w.num_n_blocks / s) == math.ceil(w.num_n_blocks / (s - 1)):
            continue  # no finer partitioning -> skip
        t = modeled_latency_us(w, s, num_cores=num_cores)
        # require a material (>2%) win to move off a smaller split — the
        # paper's plateau observation: past the knee, gains are < ~2%.
        if t < best_t * 0.98:
            best_s, best_t = s, t
    return best_s


def measured(w: DecodeWorkload, num_cores: int = DEFAULT_NUM_CORES,
             table=None, impl: Optional[str] = None) -> int:
    """Table-backed policy: decide from a calibrated ``repro.tune``
    :class:`~repro.tune.SplitTable` (nearest-L_K-bucket lookup, explicit
    counted fallback to ``paper`` for uncovered shapes).

    The table rides the :class:`~repro.plan.Planner` (``table=``) — a
    bare ``choose_num_splits(..., policy="measured")`` call must pass it
    explicitly.  ``impl`` selects the table's kernel-impl family
    (``None`` = the xla default).
    """
    if table is None:
        raise ValueError(
            "split policy 'measured' decides from a repro.tune SplitTable; "
            "pass Planner(policy='measured', table=SplitTable.load(path)) "
            "(serving: ServeConfig.tune_table_path / serve --tune-table, "
            "calibrate one with `python -m repro.launch.tune`)")
    s, _tuned = table.choose(w, impl=impl, num_cores=num_cores)
    return s


measured.needs_table = True       # excluded from analytic_policies()
measured.fallback = "paper"       # uncovered shapes / inline-eval path


POLICIES: Dict[str, Callable[..., int]] = {
    "fa3_baseline": fa3_baseline,
    "paper": paper_policy,
    "tpu_adaptive": tpu_adaptive,
    "measured": measured,
}


def get_policy(name: str) -> Callable[..., int]:
    if name not in POLICIES:
        raise KeyError(f"unknown split policy {name!r}; "
                       f"known: {available_policies()}")
    return POLICIES[name]


def available_policies() -> list:
    """Registered backend names, for CLIs / error messages."""
    return sorted(POLICIES)


def analytic_policies() -> list:
    """Backends decidable from the workload alone (no injected table) —
    the set the golden decision table and property sweeps enumerate."""
    return sorted(n for n, fn in POLICIES.items()
                  if not getattr(fn, "needs_table", False))


def choose_num_splits(w: DecodeWorkload, policy: str = "paper",
                      num_cores: int = DEFAULT_NUM_CORES,
                      table=None, impl: Optional[str] = None) -> int:
    fn = get_policy(policy)
    kw = {"table": table, "impl": impl} \
        if getattr(fn, "needs_table", False) else {}
    s = fn(w, num_cores=num_cores, **kw)
    return max(1, min(int(s), w.num_n_blocks))


# ---------------------------------------------------------------------------
# Mesh-level variant: the same decision, lifted to chips on a sharding axis
# ---------------------------------------------------------------------------


def choose_mesh_splits(w: DecodeWorkload, chips_on_axis: int,
                       policy: str = "tpu_adaptive", table=None,
                       impl: Optional[str] = None) -> int:
    """How many ways to sequence-shard the KV cache across chips.

    The paper's grid starvation, at mesh scale: when ``B x H_KV`` tiles are
    fewer than the chips available on the model axis, sequence-sharding the
    KV cache recovers the idle chips.  Constrained to divide the axis (so
    the sharding is expressible as a NamedSharding over a mesh axis).
    """
    s = choose_num_splits(w, policy=policy, num_cores=chips_on_axis,
                          table=table, impl=impl)
    # round DOWN to a divisor of chips_on_axis for even mesh sharding
    for d in range(min(s, chips_on_axis), 0, -1):
        if chips_on_axis % d == 0:
            return d
    return 1
