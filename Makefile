# Test tiers (markers registered in pytest.ini):
#   make verify      fast tier, < 5 min — plan-golden gate + serving A/B
#                    smoke first, then everything not marked
#                    slow/multidevice
#   make verify-all  the full tier-1 suite (what the roadmap's verify line runs)
#   make bench       every benchmark (one per paper table/figure + serving A/B)

PY := PYTHONPATH=src python

.PHONY: verify verify-all bench golden plan-golden tune-golden \
	serving-smoke cache-smoke prefix-smoke tune-smoke spec-smoke \
	quant-smoke shard-smoke obs-smoke

verify: plan-golden tune-golden serving-smoke cache-smoke prefix-smoke \
	tune-smoke spec-smoke quant-smoke shard-smoke obs-smoke
	$(PY) -m pytest -q -m "not multidevice and not slow"

# seconds-scale serving A/B: fused-prefill admission must stay O(1)
# planned launches per request (structural counters, not timing)
serving-smoke:
	$(PY) -m benchmarks.serving_ab --smoke

# seconds-scale cache-layout A/B: paged must match dense greedy tokens
# bit-exact while allocating/streaming fewer cache bytes (structural)
cache-smoke:
	$(PY) -m benchmarks.cache_ab --smoke

# seconds-scale prefix-sharing A/B: share_prefix must match the
# unshared engine's greedy tokens bit-exact while full-prefilling only
# the leader (followers admit as suffix launches on adopted pages) and
# allocating strictly fewer pages (structural counters + conservation)
prefix-smoke:
	$(PY) -m benchmarks.prefix_ab --smoke

# seconds-scale speculative-decoding A/B: greedy tokens bit-identical
# with speculation on/off, oracle drafter accepts ~all and emits > 1
# token per planned verify launch, page conservation after the
# reject-heavy cell (structural counters, not timing)
spec-smoke:
	$(PY) -m benchmarks.spec_ab --smoke

# seconds-scale quantized-KV A/B: fused int8 never modeled-slower than
# dequant-then-attend, fused==unfused within per-dtype tolerance
# (int8 + fp8, poisoned tails, dense + paged), int8 engine streams
# identical across the serving matrix (structural, not timing)
quant-smoke:
	$(PY) -m benchmarks.quant_ab --smoke

# seconds-scale mesh-native serving A/B: dp=4 slot shards serve 4x the
# single engine's slots and sp=4 sequence-shards decode over 4 chips,
# both with bit-identical greedy tokens, mesh_splits provenance on the
# sp plans, per-shard launch counters, and zero traced policy evals
# (re-execs itself under 8 forced host devices)
shard-smoke:
	$(PY) -m benchmarks.shard_ab --smoke

# seconds-scale observability A/B: tracing on/off must leave greedy
# tokens + PlanCacheStats bit-identical and traced policy evals at 0,
# while the on-cell dumps a schema-valid Chrome trace (request spans
# over provenance-stamped launch spans) + metrics snapshot (structural)
obs-smoke:
	$(PY) -m benchmarks.obs_ab --smoke

# seconds-scale tuning A/B: measured policy never slower than the
# analytic policies on covered shapes, counted paper fallback elsewhere,
# serving engine end-to-end on split_policy=measured (structural)
tune-smoke:
	$(PY) -m benchmarks.tune_ab --smoke

verify-all:
	$(PY) -m pytest -q

bench:
	$(PY) -m benchmarks.run

# fast gate: the Planner must reproduce the committed golden decision
# table bit-exact (plan-API drift fails here before the full tier runs)
plan-golden:
	$(PY) -m pytest -q tests/test_policy_golden.py \
	    tests/test_plan.py::test_planner_reproduces_golden_table_bit_exact

# fast gate (mirrors plan-golden for repro.tune): the committed
# reference SplitTable must be schema-valid and replay bit-exact
# through Planner(policy="measured"); regenerate intentionally with
# `python -m repro.launch.tune --reference` and commit the diff
tune-golden:
	$(PY) -m pytest -q \
	    tests/test_tune.py::test_reference_table_schema_valid \
	    tests/test_tune.py::test_reference_table_replays_bit_exact \
	    tests/test_tune.py::test_reference_table_is_regenerated_deterministically

# regenerate the policy decision golden table (commit the diff!)
golden:
	$(PY) tests/test_policy_golden.py --regen
