# Test tiers (markers registered in pytest.ini):
#   make verify      fast tier, < 120 s — everything not marked slow/multidevice
#   make verify-all  the full tier-1 suite (what the roadmap's verify line runs)
#   make bench       every benchmark (one per paper table/figure + serving A/B)

PY := PYTHONPATH=src python

.PHONY: verify verify-all bench golden

verify:
	$(PY) -m pytest -q -m "not multidevice and not slow"

verify-all:
	$(PY) -m pytest -q

bench:
	$(PY) -m benchmarks.run

# regenerate the policy decision golden table (commit the diff!)
golden:
	$(PY) tests/test_policy_golden.py --regen
